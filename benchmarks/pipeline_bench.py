"""End-to-end calibration-engine benchmark (perf trajectory guard).

Quantizes a tiny multi-layer homogeneous model twice — once with the fused
trace-cached engine (the default) and once with the legacy
fresh-jit-per-layer baseline (``trace_cache=False``) — and reports

  * XLA compilation counts for the capture/apply programs (the fused engine
    must compile O(distinct metas), the baseline O(layers)), and
  * per-layer / total quantization wall time.

Results also land in ``BENCH_pipeline.json`` at the repo root so future
PRs have a perf trajectory to regress against.  Wall times on this
container are CPU numbers; the compile counts are the portable claim.
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path

import jax

from repro.configs import get_config
from repro.core import RSQConfig, RSQPipeline
from repro.models import build_model

from benchmarks.common import Table

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_pipeline.json"

N_LAYERS = 4
CALIB_N, CALIB_T = 8, 64


def _toy_model():
    cfg = dataclasses.replace(
        get_config("llama3-8b").reduced(), dtype="float32",
        n_layers=N_LAYERS, d_model=64, vocab_size=256)
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.key(0))
    calib = jax.random.randint(jax.random.key(1), (CALIB_N, CALIB_T), 0,
                               cfg.vocab_size)
    return model, params, calib


def _run_engine(model, params, calib, *, trace_cache: bool) -> dict:
    jax.clear_caches()  # process-global jit cache would leak solver
    # compilations from one engine run into the other
    rsq = RSQConfig(bits=4, rotate=False, importance="attn_con",
                    trace_cache=trace_cache)
    pipe = RSQPipeline(model, rsq)
    t0 = time.perf_counter()
    _, report = pipe.run(params, calib, batch_size=4)
    total_s = time.perf_counter() - t0
    layer_s = [l["seconds"] for l in report["layers"].values()]
    return {
        "trace_cache": trace_cache,
        "n_layers": len(layer_s),
        "total_s": round(total_s, 3),
        "per_layer_s": layer_s,
        "mean_layer_s": round(sum(layer_s) / len(layer_s), 3),
        "compiles": dict(pipe.trace_counts),
    }


def run(table: Table | None = None):
    table = table or Table("pipeline")
    model, params, calib = _toy_model()

    # discarded warm-up: one-time process costs (backend init, primitive
    # lowering caches) otherwise land entirely on whichever engine runs first
    _run_engine(model, params, calib, trace_cache=True)
    fused = _run_engine(model, params, calib, trace_cache=True)
    base = _run_engine(model, params, calib, trace_cache=False)

    table.add(
        "fused_engine", fused["total_s"] * 1e6,
        f"compiles_capture={fused['compiles']['capture']} "
        f"compiles_apply={fused['compiles']['apply']} "
        f"mean_layer_s={fused['mean_layer_s']}")
    table.add(
        "per_layer_jit_baseline", base["total_s"] * 1e6,
        f"compiles_capture={base['compiles']['capture']} "
        f"compiles_apply={base['compiles']['apply']} "
        f"mean_layer_s={base['mean_layer_s']}")
    speedup = base["total_s"] / max(fused["total_s"], 1e-9)
    table.add("fused_vs_baseline", 0.0,
              f"speedup={speedup:.2f}x "
              f"compile_ratio={base['compiles']['capture']}"
              f":{fused['compiles']['capture']}")

    payload = {"fused": fused, "baseline": base,
               "speedup": round(speedup, 3),
               "backend": jax.default_backend()}
    OUT_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    return table


if __name__ == "__main__":
    run()
